"""Correctness of the hillclimb features: grouped MoE dispatch, int8 KV
cache, remat_span — each must preserve model semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.models import lm
from repro.models import transformer as T
from repro.optim.adamw import AdamW

KEY = jax.random.PRNGKey(5)


def test_grouped_moe_matches_global_without_drops():
    base = dataclasses.replace(reduce_for_smoke(get_config("qwen2-moe-a2.7b")),
                               dtype="float32")
    # capacity high enough that neither dispatch drops tokens
    moe = dataclasses.replace(base.moe, capacity_factor=8.0)
    cfg_g = dataclasses.replace(base, moe=moe, moe_dispatch="global")
    cfg_r = dataclasses.replace(base, moe=moe, moe_dispatch="grouped")
    params = T.tree_init(T.param_defs(cfg_g), cfg_g, KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, cfg_g.vocab)}
    lg, _, _ = lm.forward(cfg_g, params, batch, mode="train")
    lr, _, _ = lm.forward(cfg_r, params, batch, mode="train")
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lr),
                               atol=1e-4, rtol=1e-4)


def test_int8_kv_decode_close_to_bf16():
    base = dataclasses.replace(reduce_for_smoke(get_config("llama3-8b")),
                               dtype="float32")
    cfg8 = dataclasses.replace(base, kv_dtype="int8")
    params = T.tree_init(T.param_defs(base), base, KEY)
    toks = jax.random.randint(KEY, (2, 33), 0, base.vocab)

    def staged(cfg):
        caches = T.init_cache(cfg, 2, 40)
        caches, _ = lm.make_prefill_step(cfg)(
            params, {"tokens": toks[:, :32]}, caches)
        _, lg = lm.make_decode_step(cfg)(
            params, {"tokens": toks[:, 32:33],
                     "pos": jnp.full((2, 1), 32, jnp.int32)}, caches)
        return np.asarray(lg, np.float32)

    ref = staged(base)
    got = staged(cfg8)
    # int8 KV quantisation noise on logits stays small
    assert np.max(np.abs(got - ref)) < 0.5, np.max(np.abs(got - ref))
    # and top-1 predictions agree
    np.testing.assert_array_equal(got.argmax(-1), ref.argmax(-1))


def test_remat_span_preserves_loss_and_grads():
    base = dataclasses.replace(reduce_for_smoke(get_config("llama3-8b")),
                               dtype="float32", n_layers=4)
    spanned = dataclasses.replace(base, remat_span=2)
    params = T.tree_init(T.param_defs(base), base, KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, base.vocab),
             "labels": jax.random.randint(KEY, (2, 32), 0, base.vocab)}
    opt = AdamW(lr=1e-3)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    s1, m1 = jax.jit(lm.make_train_step(base, opt))(state, batch)
    s2, m2 = jax.jit(lm.make_train_step(spanned, opt))(state, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
