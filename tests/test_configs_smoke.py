"""Per-arch smoke: reduced config, one forward/train step, shapes + no NaNs.

Full configs are exercised only via the dry-run (ShapeDtypeStruct); these
reduced configs share the family code paths (GQA/bias/M-RoPE/MoE/wkv/RG-LRU).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config, reduce_for_smoke, \
    shape_applicable
from repro.models import lm
from repro.models import transformer as T
from repro.optim.adamw import AdamW

KEY = jax.random.PRNGKey(0)
B, S = 2, 64


def make_batch(cfg, with_labels=True):
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    if with_labels:
        batch["labels"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    if cfg.vision_stub:
        batch["vision_embeds"] = jax.random.normal(
            KEY, (B, S, cfg.d_model), jnp.bfloat16)
        batch["vision_mask"] = jnp.zeros((B, S), jnp.bool_).at[:, :8].set(True)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = reduce_for_smoke(get_config(arch))
    params = T.tree_init(T.param_defs(cfg), cfg, KEY)
    batch = make_batch(cfg)
    logits, _, aux = lm.forward(cfg, params, batch, mode="train")
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    opt = AdamW(lr=1e-3)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    step = jax.jit(lm.make_train_step(cfg, opt))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2["step"]) == 1
    # params actually changed
    w0 = jax.tree.leaves(state["params"])[0]
    w1 = jax.tree.leaves(state2["params"])[0]
    assert not np.allclose(np.asarray(w0, np.float32),
                           np.asarray(w1, np.float32))


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "rwkv6-1.6b",
                                  "recurrentgemma-2b", "qwen2-moe-a2.7b",
                                  "qwen2-vl-7b"])
def test_prefill_decode(arch):
    cfg = reduce_for_smoke(get_config(arch))
    params = T.tree_init(T.param_defs(cfg), cfg, KEY)
    batch = make_batch(cfg, with_labels=False)
    caches0 = T.init_cache(cfg, B, S + 8)
    prefill = jax.jit(lm.make_prefill_step(cfg))
    caches, last = prefill(params, batch, caches0)
    assert last.shape == (B, cfg.vocab)
    decode = jax.jit(lm.make_decode_step(cfg))
    tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
    dbatch = {"tokens": tok, "pos": jnp.full((B, 1), S, jnp.int32)}
    if cfg.attention is not None and cfg.attention.mrope_sections:
        dbatch["pos"] = jnp.broadcast_to(
            jnp.full((B, 1, 1), S, jnp.int32), (B, 1, 3))
    caches, lg = decode(params, dbatch, caches)
    assert lg.shape == (B, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(lg.astype(jnp.float32))))


def test_long_500k_applicability():
    """long_500k runs only for sub-quadratic archs (DESIGN.md §7)."""
    shape = SHAPES["long_500k"]
    expected_run = {"rwkv6-1.6b", "recurrentgemma-2b"}
    for arch in ARCHS:
        ok, why = shape_applicable(get_config(arch), shape)
        assert ok == (arch in expected_run), (arch, why)


def test_param_counts_match_published():
    targets = {"qwen2-0.5b": 0.494e9, "llama3-8b": 8.03e9,
               "qwen2.5-14b": 14.8e9, "grok-1-314b": 316e9,
               "rwkv6-1.6b": 1.6e9, "qwen2-moe-a2.7b": 14.3e9}
    for arch, want in targets.items():
        cfg = get_config(arch)
        ab = T.tree_abstract(T.param_defs(cfg), cfg)
        got = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(ab))
        assert abs(got - want) / want < 0.06, (arch, got, want)
    # MoE active < total
    moe = get_config("qwen2-moe-a2.7b")
    assert moe.n_active_params < 0.25 * moe.n_params
