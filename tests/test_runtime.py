"""Fault tolerance + elastic scaling unit tests."""

import pytest

from repro.runtime.elastic import largest_pow2_leq, plan_resize
from repro.faults.retry import TransientIOError
from repro.runtime.fault import (Heartbeat, StepFailure, StepGuard,
                                 StragglerMonitor)


class TestStepGuard:
    def test_retries_then_succeeds(self):
        calls = {"n": 0}

        def flaky(state, x):
            calls["n"] += 1
            if calls["n"] < 3:
                raise TransientIOError("transient")
            return state + x

        g = StepGuard(max_retries=2)
        assert g.run(flaky, 1, 2) == 3
        assert g.failures == 2

    def test_bare_runtime_error_is_not_retried(self):
        """The catch-all that masked genuine bugs as retriable is gone:
        an untyped RuntimeError propagates on the first attempt."""
        calls = {"n": 0}

        def buggy(state):
            calls["n"] += 1
            raise RuntimeError("a genuine bug, not a transient")

        g = StepGuard(max_retries=3)
        with pytest.raises(RuntimeError, match="genuine bug"):
            g.run(buggy, None)
        assert calls["n"] == 1 and g.failures == 0

    def test_completion_timeout_is_retriable(self):
        from repro.cplane import CompletionTimeout
        calls = {"n": 0}

        def slow(state):
            calls["n"] += 1
            if calls["n"] == 1:
                raise CompletionTimeout("doorbell stuck")
            return state

        g = StepGuard(max_retries=1)
        assert g.run(slow, 7) == 7
        assert g.failures == 1

    def test_restore_path(self):
        def always_fail_on_bad_state(state, x):
            if state == "corrupt":
                raise StepFailure("bad state")
            return state + x

        g = StepGuard(max_retries=1, on_restore=lambda: 10)
        assert g.run(always_fail_on_bad_state, "corrupt", 5) == 15
        assert g.restores == 1

    def test_raises_without_restore(self):
        g = StepGuard(max_retries=1)
        with pytest.raises(StepFailure):
            g.run(lambda s: (_ for _ in ()).throw(TransientIOError("x")),
                  None)

    def test_post_restore_replay_is_guarded(self):
        """A transient failure right after the restore must retry under
        the same guard instead of crashing the run (ISSUE 5)."""
        calls = {"post_restore": 0}

        def flaky(state, x):
            if state == "corrupt":
                raise StepFailure("bad state")
            calls["post_restore"] += 1
            if calls["post_restore"] == 1:
                raise TransientIOError("transient right after restore")
            return state + x

        g = StepGuard(max_retries=1, on_restore=lambda: 10)
        assert g.run(flaky, "corrupt", 5) == 15
        assert g.restores == 1
        assert g.failures == 3      # 2 corrupt-state + 1 post-restore

    def test_guarded_replay_exhaustion_raises_step_failure(self):
        g = StepGuard(max_retries=1, on_restore=lambda: "still-bad")

        def always(state, *a):
            raise TransientIOError("x")

        with pytest.raises(StepFailure, match="post-restore replay"):
            g.run(always, None)
        assert g.restores == 1 and g.failures == 4

    def test_no_backoff_after_final_attempt(self, monkeypatch):
        """The retry backoff buys time for the NEXT attempt; after the
        last one it is pure dead time and must be skipped."""
        from repro.runtime import fault
        sleeps = []
        monkeypatch.setattr(fault.time, "sleep",
                            lambda s: sleeps.append(s))
        g = StepGuard(max_retries=2)

        def always(state):
            raise TransientIOError("x")

        with pytest.raises(StepFailure):
            g.run(always, None)
        # 3 attempts -> sleeps only between them, never after the last
        assert len(sleeps) == 2
        assert sleeps == [0.01, 0.02]


class TestStraggler:
    def test_flags_slow_step(self):
        m = StragglerMonitor(threshold=2.0, warmup=2)
        for i in range(5):
            assert not m.record(i, 1.0)
        assert m.record(5, 3.0)
        assert m.stragglers == [5]
        # baseline unpolluted by the straggler sample
        assert m.ewma < 1.5

    def test_warmup_never_flags(self):
        m = StragglerMonitor(warmup=3)
        assert not m.record(0, 1.0)
        assert not m.record(1, 100.0)


class TestHeartbeat:
    def test_dead_worker_detection(self):
        hb = Heartbeat(timeout_s=10.0)
        hb.beat(0, t=100.0)
        hb.beat(1, t=105.0)
        assert hb.dead_workers(now=112.0) == [0]
        assert hb.dead_workers(now=120.0) == [0, 1]


class TestElastic:
    def test_plan_keeps_model_axis(self):
        plan = plan_resize(alive_workers=[0, 1, 2, 3], chips_per_worker=64,
                           model_parallel=16, global_batch=256)
        assert plan.mesh_shape == (16, 16)
        assert plan.num_shards == 4
        assert sorted(plan.data_shards.values()) == [0, 1, 2, 3]

    def test_plan_after_losing_workers(self):
        plan = plan_resize(alive_workers=[0, 2, 3], chips_per_worker=64,
                           model_parallel=16, global_batch=256)
        data, model = plan.mesh_shape
        assert model == 16
        assert data * model <= 3 * 64
        assert 256 % data == 0
        assert plan.data_shards == {0: 0, 2: 1, 3: 2}

    def test_plan_shrinks_tp_when_needed(self):
        plan = plan_resize(alive_workers=[0], chips_per_worker=8,
                           model_parallel=16, global_batch=64)
        assert plan.mesh_shape[1] <= 8

    def test_no_workers_raises(self):
        with pytest.raises(ValueError):
            plan_resize([], 8, 4, 64)

    def test_pow2(self):
        assert largest_pow2_leq(9) == 8
        assert largest_pow2_leq(16) == 16
        assert largest_pow2_leq(1) == 1
