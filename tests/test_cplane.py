"""Completion-plane tests (DESIGN.md §6).

The contract under every async primitive in the repo: settle-once
states, timeouts/deadlines/cancellation, callbacks, heterogeneous
composition (channel Transfer + verbs doorbell + tier PendingIO raced
in ONE wait_any), reactor telemetry, and the four legacy surfaces
(Transfer.wait / WorkItem.done / PendingIO.wait / _Doorbell.wait /
CompletionQueue.wait) all being served by repro.cplane.
"""
import threading
import time

import numpy as np
import pytest

from repro import cplane
from repro.cplane import (Completion, CompletionCancelled, CompletionState,
                          CompletionTimeout, Reactor, as_completed,
                          wait_all, wait_any)
from repro.core.channels import ChannelPool, Direction
from repro.core.queues import QueueEngine, WorkItem
from repro.rmem import (LocalHostBackend, MemoryNode, MemoryRegion,
                        PendingIO, QueuePair, RemoteBackend)


def _settle_later(c: Completion, dt: float, result=None):
    t = threading.Thread(target=lambda: (time.sleep(dt),
                                         c.succeed(result)), daemon=True)
    t.start()
    return t


class TestCompletion:
    def test_states_and_result_idempotent(self):
        c = Completion()
        assert c.state is CompletionState.PENDING
        assert not c.poll()
        assert c.succeed(41)
        assert not c.succeed(99)            # settle exactly once
        assert c.state is CompletionState.DONE
        assert c.wait(0.1) == 41
        assert c.result() == 41             # idempotent

    def test_error_raises_from_wait_and_result(self):
        c = Completion.failed(IOError("boom"))
        assert c.state is CompletionState.ERROR
        with pytest.raises(IOError, match="boom"):
            c.wait(0.1)
        with pytest.raises(IOError, match="boom"):
            c.result()

    def test_result_before_settle_raises(self):
        with pytest.raises(RuntimeError, match="not settled"):
            Completion().result()

    def test_wait_timeout_is_timeouterror_subclass(self):
        c = Completion()
        with pytest.raises(CompletionTimeout):
            c.wait(0.02)
        with pytest.raises(TimeoutError):   # legacy except-clauses hold
            c.wait(0.02)
        assert c.state is CompletionState.PENDING   # still waitable
        c.succeed("late")
        assert c.wait(0.1) == "late"

    def test_cancellation(self):
        c = Completion()
        assert c.cancel()
        assert c.state is CompletionState.CANCELLED
        with pytest.raises(CompletionCancelled):
            c.wait(0.1)
        assert not c.cancel()               # second cancel lost the race
        d = Completion.done(1)
        assert not d.cancel()               # settled completions can't
        assert d.result() == 1

    def test_deadline_expiry(self):
        c = Completion(deadline=time.monotonic() + 0.03)
        t0 = time.monotonic()
        with pytest.raises(CompletionTimeout, match="deadline"):
            c.wait(5.0)                     # deadline wins over timeout
        assert time.monotonic() - t0 < 1.0

    def test_callback_after_done_fires_immediately(self):
        c = Completion.done("x")
        seen = []
        c.add_callback(lambda comp: seen.append(comp.result()))
        assert seen == ["x"]

    def test_callback_fires_on_settle_from_producer_thread(self):
        c = Completion()
        seen = []
        c.add_callback(lambda comp: seen.append(comp.state))
        _settle_later(c, 0.01).join()
        assert seen == [CompletionState.DONE]

    def test_lazy_result_runs_on_consumer(self):
        ran = []
        c = Completion()
        c.succeed_lazy(lambda: ran.append(1) or "lazy")
        assert c.poll() and not ran         # settled, not yet produced
        assert c.wait(0.1) == "lazy"
        assert c.result() == "lazy" and ran == [1]   # produced once


class TestComposition:
    def test_wait_any_returns_first_settlers(self):
        fast, slow = Completion(), Completion()
        _settle_later(fast, 0.01, "fast")
        done = wait_any([slow, fast], timeout=5.0)
        assert done == [fast]
        slow.succeed("slow")

    def test_wait_any_timeout(self):
        with pytest.raises(CompletionTimeout):
            wait_any([Completion()], timeout=0.02)

    def test_wait_all_results_in_input_order(self):
        cs = [Completion() for _ in range(3)]
        for i, c in enumerate(cs):
            _settle_later(c, 0.005 * (3 - i), i)
        assert wait_all(cs, timeout=5.0) == [0, 1, 2]

    def test_as_completed_yields_in_settle_order(self):
        a, b = Completion(), Completion()
        _settle_later(b, 0.005, "b")
        _settle_later(a, 0.05, "a")
        order = [c.result() for c in as_completed([a, b], timeout=5.0)]
        assert order == ["b", "a"]

    def test_wait_any_heterogeneous_transfer_doorbell_pendingio(self):
        """The tentpole claim: a channel Transfer, a verbs doorbell and a
        tier PendingIO race in ONE wait_any."""
        node = MemoryNode("hetero", 1 << 20)
        qp = QueuePair(node, doorbell_batch=8)
        mr = MemoryRegion(np.ones(4096, np.uint8))
        addr = node.alloc(4096)
        backend = LocalHostBackend(4, 256)
        pool = ChannelPool(2)
        try:
            qp.post_write(mr, 0, addr, 4096)
            bell = qp.ring_doorbell()
            tr = pool.h2c(np.ones(1024, np.float32))
            io = backend.load_many_async([0, 2])    # settles inline
            everything = [bell.completion, tr, io]
            # all three producers settle; drain them through one plane
            remaining = list(everything)
            for c in as_completed(list(everything), timeout=10.0):
                remaining.remove(c)
            assert remaining == []
            assert io.wait(1.0).shape == (2, 256)
            tr.wait(1.0)
            bell.wait(1.0)
        finally:
            pool.close()
            node.close()

    def test_doorbell_completion_races_in_wait_any(self):
        node = MemoryNode("race", 1 << 20, latency_s=0.03)
        qp = QueuePair(node, doorbell_batch=1)
        mr = MemoryRegion(np.zeros(512, np.uint8))
        addr = node.alloc(512)
        try:
            with qp.collect_doorbells() as coll:
                qp.post_write(mr, 0, addr, 512)     # batch=1: auto-rings
            (bell_c,) = coll.completions()
            assert not bell_c.poll()                # RTT still running
            done = wait_any([bell_c, Completion()], timeout=5.0)
            assert done == [bell_c]
        finally:
            node.close()


class TestPendingIOTimeout:
    def test_legacy_finalize_timeout_raises_completion_timeout(self):
        """Uniform satellite contract: whatever TimeoutError shape the
        backend's fence raises, PendingIO.wait surfaces a single
        cplane.CompletionTimeout — and stays waitable for a retry."""
        calls = []

        def finalize(timeout):
            calls.append(timeout)
            if len(calls) == 1:
                raise TimeoutError("backend-specific shape")
            return "eventually"

        io = PendingIO(finalize)
        with pytest.raises(CompletionTimeout):
            io.wait(0.01)
        assert io.wait(1.0) == "eventually"         # retry succeeded

    def test_reactive_deps_timeout_raises_completion_timeout(self):
        never = Completion()
        io = PendingIO(lambda t: "x", deps=[never])
        assert io.reactive and not io.poll()
        with pytest.raises(CompletionTimeout):
            io.wait(0.02)
        never.succeed(None)
        assert io.wait(1.0) == "x"

    def test_remote_backend_timeout_uniform(self):
        """A clogged node makes the fetch miss its budget: the raised
        type is cplane.CompletionTimeout, not a verbs-specific shape."""
        node = MemoryNode("slowpoke", (1 << 21) + (1 << 15),
                          latency_s=0.2)
        be = RemoteBackend(n_pages=2, page_bytes=4096, nodes=[node])
        try:
            io = be.load_many_async([0, 1])
            with pytest.raises(CompletionTimeout):
                io.wait(0.01)
            io.wait(5.0)                            # still joinable
        finally:
            be.close()
            node.close()

    def test_failed_dep_settles_reactive_handle_as_error(self):
        """A doorbell/member failure must be visible in the handle's
        STATE (and telemetry), not only at result() — a failed fetch
        reported as DONE would mislead wait_any racers and health
        counters."""
        dep = Completion.failed(IOError("wr failed"))

        def finalize(_t):
            raise IOError("wr failed")
        io = PendingIO(finalize, deps=[dep])
        assert io.poll()
        assert io.state is CompletionState.ERROR
        with pytest.raises(IOError, match="wr failed"):
            io.wait(0.1)

    def test_unregistered_source_not_resurrected(self):
        """Late settles/records after the owner unregistered must not
        re-create the source entry (unbounded telemetry growth)."""
        r = Reactor()
        r.register_source("gone")
        c = r.completion("gone")
        r.unregister_source("gone")
        c.succeed(None)                     # straggler settle
        r.record("gone", 0.001, nbytes=8)   # straggler sync sample
        assert r.stats_for("gone") is None

    def test_ready_and_legacy_error_settles(self):
        assert PendingIO.ready(7).wait(0.01) == 7

        def boom(_t):
            raise IOError("fetch failed")
        io = PendingIO(boom)
        with pytest.raises(IOError):
            io.wait(0.1)
        with pytest.raises(IOError):
            io.wait(0.1)                            # error is sticky


class TestWorkItem:
    def test_default_factory_builds_completions(self):
        """Satellite: no __post_init__ None-dance — the dataclass fields
        ARE completions from construction."""
        item = WorkItem(payload=np.zeros(4), direction=Direction.H2C)
        assert isinstance(item.done, Completion)
        assert isinstance(item.assigned, Completion)
        assert not item.done.poll() and not item.assigned.poll()
        other = WorkItem(payload=None, direction=Direction.C2H)
        assert item.done is not other.done          # per-instance events

    def test_queue_engine_waits_through_cplane(self):
        with QueueEngine(n_channels=1) as qe:
            qe.create_queue("q")
            item = qe.submit("q", np.full(64, 3.0, np.float32),
                             Direction.H2C)
            out = qe.wait(item, timeout=30.0)
            assert float(np.asarray(out)[0]) == 3.0
            assert item.assigned.poll() and item.done.poll()

    def test_queue_engine_wait_timeout_type(self):
        item = WorkItem(payload=None, direction=Direction.H2C)
        with QueueEngine(n_channels=1) as qe:
            with pytest.raises(CompletionTimeout):
                qe.wait(item, timeout=0.02)         # never enqueued


class TestReactorTelemetry:
    def test_counters_and_inflight_gauge(self):
        r = Reactor(ewma_alpha=0.5)
        r.register_source("src", mode="interrupt")
        c1 = r.completion("src", nbytes=100)
        c2 = r.completion("src", nbytes=300)
        st = r.stats_for("src")
        assert st.submitted == 2 and st.inflight == 2
        c1.succeed(None)
        st = r.stats_for("src")
        assert st.completed == 1 and st.inflight == 1
        assert st.ewma_latency_s > 0
        c2.fail(IOError("x"))
        st = r.stats_for("src")
        assert st.completed == 2 and st.inflight == 0 and st.errors == 1
        assert st.bytes_moved == 400

    def test_record_one_shot_sample(self):
        r = Reactor()
        r.register_source("sync")
        r.record("sync", 0.001, nbytes=512)
        r.record("sync", 0.003, nbytes=512)
        st = r.stats_for("sync")
        assert st.submitted == st.completed == 2
        assert st.inflight == 0
        assert 0.001 < st.ewma_latency_s < 0.003    # EWMA between samples
        assert st.ewma_gbps > 0

    def test_channel_pool_feeds_private_reactor(self):
        r = Reactor()
        pool = ChannelPool(2, reactor=r, source="mypool")
        try:
            trs = [pool.h2c(np.ones(256, np.float32)) for _ in range(3)]
            wait_all(trs, timeout=30.0)
            st = r.stats_for("mypool")
            assert st.submitted == 3 and st.completed == 3
            assert st.bytes_moved == 3 * 1024
            assert st.ewma_latency_s > 0
        finally:
            pool.close()
        assert r.stats_for("mypool") is None        # unregistered on close

    def test_telemetry_snapshot_shape(self):
        r = Reactor()
        r.register_source("a")
        r.record("a", 0.001, nbytes=10)
        snap = r.telemetry()
        assert set(snap) == {"a"}
        for key in ("mode", "submitted", "completed", "inflight",
                    "ewma_latency_s", "ewma_gbps", "bytes_moved"):
            assert key in snap["a"]

    def test_record_does_not_erode_async_inflight(self):
        """A source shared between async completions and sync record()
        samples (the verbs ':page' source) must keep its genuine
        in-flight count — record() nets to zero on the gauge."""
        r = Reactor()
        r.register_source("shared")
        c = r.completion("shared")          # one genuinely in flight
        for _ in range(5):
            r.record("shared", 0.001, nbytes=64)
        assert r.stats_for("shared").inflight == 1
        c.succeed(None)
        assert r.stats_for("shared").inflight == 0

    def test_repeated_bounded_wait_any_leaves_no_callbacks(self):
        """Serve's per-step grace polls wait_any on the SAME pending
        completions; timed-out waits must deregister their waiter."""
        c = Completion()
        for _ in range(5):
            with pytest.raises(CompletionTimeout):
                wait_any([c], timeout=0.002)
        assert len(c._callbacks) == 0
        c.succeed("late")
        assert wait_any([c], timeout=1.0) == [c]

    def test_default_reactor_is_process_wide(self):
        assert cplane.default_reactor() is cplane.default_reactor()
