"""Analytical bandwidth model vs the paper's measured anchors (§6)."""
import pytest

from repro.core.analytical import (bandwidth_gbps, paper_pcie_bram,
                                   paper_pcie_ddr4, tpu_host_path,
                                   tpu_ici_path)
from repro.core.channels import Direction

MB = 1 << 20

# (model, size, channels, direction, paper_value_gbps, rel_tol)
ANCHORS = [
    # Fig 10: DDR4 C2H single channel peaks ~12 GB/s
    (paper_pcie_ddr4, 4 * MB, 1, Direction.C2H, 12.0, 0.25),
    # Fig 9: DDR4 H2C single channel peaks ~10.8 GB/s
    (paper_pcie_ddr4, 4 * MB, 1, Direction.H2C, 10.8, 0.25),
    # Fig 10: multi-channel C2H 13-14 GB/s
    (paper_pcie_ddr4, 4 * MB, 4, Direction.C2H, 13.5, 0.25),
    # Fig 8: BRAM ~7.5 (H2C) / 7.8 (C2H) at 1 MB
    (paper_pcie_bram, MB, 1, Direction.H2C, 7.54, 0.25),
    (paper_pcie_bram, MB, 1, Direction.C2H, 7.77, 0.25),
]


@pytest.mark.parametrize("model,size,ch,direction,paper,tol", ANCHORS)
def test_model_matches_paper_anchor(model, size, ch, direction, paper, tol):
    got = bandwidth_gbps(model(), size, ch, direction)
    assert abs(got - paper) / paper < tol, (got, paper)


def test_bandwidth_rises_with_size():
    m = paper_pcie_ddr4()
    sizes = [1 << 12, 1 << 16, 1 << 20, 1 << 24]
    bws = [bandwidth_gbps(m, s, 1, Direction.C2H) for s in sizes]
    assert all(a < b for a, b in zip(bws, bws[1:]))


def test_multichannel_aggregates_with_diminishing_returns():
    m = paper_pcie_ddr4()
    b = [bandwidth_gbps(m, 8 * MB, c, Direction.C2H) for c in (1, 2, 4, 8)]
    assert b[0] < b[1] < b[2] <= b[3] + 1e-9
    assert (b[1] - b[0]) > (b[3] - b[2])  # diminishing
    assert b[3] <= m.link_gbps


def test_c2h_beats_h2c():
    m = paper_pcie_ddr4()
    assert bandwidth_gbps(m, MB, 1, Direction.C2H) > \
        bandwidth_gbps(m, MB, 1, Direction.H2C)


def test_contention_factor_matches_paper():
    """Fig 11: 10.8 -> ~9.5 GB/s when the second master is present."""
    m = paper_pcie_ddr4()
    free = bandwidth_gbps(m, 4 * MB, 1, Direction.H2C)
    busy = bandwidth_gbps(m, 4 * MB, 1, Direction.H2C, contended=True)
    assert 0.8 < busy / free < 0.95


def test_tpu_paths_ordering():
    """HBM > host PCIe; ICI between them for small messages."""
    host = bandwidth_gbps(tpu_host_path(), 16 * MB, 4, Direction.C2H)
    ici = bandwidth_gbps(tpu_ici_path(), 16 * MB, 1, Direction.C2H)
    assert host < 32.0
    assert ici < 50.0
    assert ici > host  # ICI link faster than PCIe host path
