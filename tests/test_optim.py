"""AdamW math, schedules, dtype policies; gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import AdamW, for_arch
from repro.optim.compression import (compress_for_allreduce,
                                     dequantize_int8, ef_compress, ef_init,
                                     quantize_int8)


def test_adamw_first_step_matches_closed_form():
    opt = AdamW(lr=0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                warmup_steps=1, decay_steps=10**9)
    p = {"w": jnp.ones((4,), jnp.float32)}
    g = {"w": jnp.full((4,), 0.5)}
    new_p, st = opt.update(p, g, opt.init(p), jnp.zeros((), jnp.int32))
    # bias-corrected m/bc1 = g, v/bc2 = g^2 -> update = g/(|g|+eps) = 1
    np.testing.assert_allclose(np.asarray(new_p["w"]), 1.0 - 0.1, rtol=1e-5)


def test_adamw_weight_decay_skips_vectors():
    opt = AdamW(lr=0.1, weight_decay=0.5, warmup_steps=1, decay_steps=10**9)
    p = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    g = jax.tree.map(jnp.zeros_like, p)
    new_p, _ = opt.update(p, g, opt.init(p), jnp.zeros((), jnp.int32))
    assert float(new_p["w"][0, 0]) < 1.0   # decayed
    assert float(new_p["b"][0]) == 1.0     # 1-D: no decay


def test_schedule_warmup_and_decay():
    opt = AdamW(lr=1.0, warmup_steps=10, decay_steps=100, min_lr_frac=0.1)
    lr0 = float(opt.schedule(jnp.asarray(0)))
    lr9 = float(opt.schedule(jnp.asarray(9)))
    lr_end = float(opt.schedule(jnp.asarray(1000)))
    assert lr0 < lr9 <= 1.0
    assert np.isclose(lr_end, 0.1, rtol=1e-3)


def test_bf16_state_and_master_weights():
    opt = AdamW(lr=1e-2, state_dtype="bfloat16", master_weights=True,
                warmup_steps=1, decay_steps=10**9, weight_decay=0.0)
    p = {"w": jnp.ones((8,), jnp.bfloat16)}
    st = opt.init(p)
    assert st["m"]["w"].dtype == jnp.bfloat16
    assert st["master"]["w"].dtype == jnp.float32
    g = {"w": jnp.full((8,), 0.25, jnp.bfloat16)}
    new_p, st2 = opt.update(p, g, st, jnp.zeros((), jnp.int32))
    assert new_p["w"].dtype == jnp.bfloat16
    assert st2["master"]["w"].dtype == jnp.float32


def test_for_arch_grok_policy():
    assert for_arch("grok-1-314b").state_dtype == "bfloat16"
    assert for_arch("llama3-8b").state_dtype == "float32"


class TestCompression:
    def test_int8_roundtrip_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 3.0
        q, s = quantize_int8(x)
        err = np.abs(np.asarray(dequantize_int8(q, s) - x))
        assert err.max() <= float(s) * 0.5 + 1e-6

    def test_error_feedback_telescopes(self):
        """Sum of EF-compressed grads converges to sum of true grads."""
        key = jax.random.PRNGKey(1)
        grads = [{"w": 0.1 * jax.random.normal(jax.random.fold_in(key, i),
                                               (64,))} for i in range(30)]
        st = ef_init(grads[0])
        acc_q = np.zeros(64)
        acc_true = np.zeros(64)
        for g in grads:
            qt, st = ef_compress(g, st)
            acc_q += np.asarray(dequantize_int8(*jax.tree.leaves(
                qt, is_leaf=lambda x: isinstance(x, tuple))[0]))
            acc_true += np.asarray(g["w"])
        resid = np.abs(np.asarray(jax.tree.leaves(st.residual)[0]))
        np.testing.assert_allclose(acc_q + resid * 0, acc_true,
                                   atol=float(resid.max()) + 1e-3)

    def test_hook_schemes(self):
        g = {"w": jnp.ones((16,), jnp.float32)}
        wire, dec, _ = compress_for_allreduce(g, "bf16")
        assert jax.tree.leaves(wire)[0].dtype == jnp.bfloat16
        back = dec(wire)
        np.testing.assert_allclose(np.asarray(back["w"]), 1.0)
        st = ef_init(g)
        wire, dec, st2 = compress_for_allreduce(g, "int8_ef", st)
        back = dec(wire)
        np.testing.assert_allclose(np.asarray(back["w"]), 1.0, atol=0.02)
