"""rmem subsystem tests: verbs, memory nodes, address map, tiered store,
serve integration, and far checkpoints (ISSUE 1 acceptance criteria)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.analytical import (bandwidth_gbps, doorbell_bandwidth_gbps,
                                   far_memory_path)
from repro.core.channels import CompletionMode, Direction
from repro.rmem import (AddressMap, CompletionQueue, LocalHostBackend,
                        MemoryNode, MemoryRegion, QueuePair, RemoteBackend,
                        TieredStore, WCStatus, make_backend)


class TestVerbs:
    def test_one_sided_write_read_roundtrip_bit_exact(self):
        with MemoryNode("n0", 1 << 20) as node:
            src = np.random.default_rng(0).integers(
                0, 256, 4096, dtype=np.uint8)
            addr = node.alloc(4096)
            qp = QueuePair(node)
            wc = qp.write(MemoryRegion(src), 0, addr, 4096)
            assert wc.status == WCStatus.SUCCESS
            back = np.zeros(4096, np.uint8)
            qp.read(MemoryRegion(back), 0, addr, 4096)
            np.testing.assert_array_equal(back, src)
            assert node.bytes_in == 4096 and node.bytes_out == 4096

    def test_doorbell_batching_fewer_completions(self):
        n = 8
        with MemoryNode("n1", 1 << 20) as node:
            mr = MemoryRegion(np.ones(n * 512, np.uint8))
            qp = QueuePair(node, doorbell_batch=n)
            base = node.alloc(n * 512)
            for i in range(n):
                qp.post_write(mr, i * 512, base + i * 512, 512)
            qp.flush()
            assert qp.wrs_posted == n
            assert qp.cq.n_completions < n
            assert qp.cq.n_completions == 1
            np.testing.assert_array_equal(
                node.pool[base:base + n * 512], np.ones(n * 512, np.uint8))

    def test_batched_completion_carries_batch_totals(self):
        with MemoryNode("n2", 1 << 20) as node:
            mr = MemoryRegion(np.ones(4 * 256, np.uint8))
            qp = QueuePair(node, doorbell_batch=4)
            base = node.alloc(4 * 256)
            for i in range(4):
                qp.post_write(mr, i * 256, base + i * 256, 256)
            wc = qp.cq.wait(1)[0]
            assert wc.batch_wrs == 4
            assert wc.batch_bytes == 4 * 256

    def test_interrupt_mode_fires_callback(self):
        import threading
        fired = threading.Event()
        cq = CompletionQueue(CompletionMode.INTERRUPT,
                             on_completion=lambda wc: fired.set())
        with MemoryNode("n3", 1 << 16) as node:
            qp = QueuePair(node, cq=cq)
            qp.write(MemoryRegion(np.ones(64, np.uint8)), 0,
                     node.alloc(64), 64)
            assert fired.wait(10)

    def test_mr_bounds_checked_at_post(self):
        with MemoryNode("n4", 1 << 16) as node:
            qp = QueuePair(node)
            mr = MemoryRegion(np.ones(64, np.uint8))
            with pytest.raises(ValueError, match="out of bounds"):
                qp.post_write(mr, 32, 0, 64)

    def test_out_of_pool_write_surfaces_error(self):
        with MemoryNode("n5", 1024) as node:
            qp = QueuePair(node, doorbell_batch=4)
            qp.post_write(MemoryRegion(np.ones(512, np.uint8)), 0, 900, 512)
            with pytest.raises(IndexError, match="out of pool"):
                qp.flush()

    def test_qp_stats_account_traffic(self):
        with MemoryNode("n6", 1 << 16) as node:
            qp = QueuePair(node)
            addr = node.alloc(256)
            qp.write(MemoryRegion(np.ones(256, np.uint8)), 0, addr, 256)
            buf = np.zeros(256, np.uint8)
            qp.read(MemoryRegion(buf), 0, addr, 256)
            s = qp.stats()
            assert s["bytes_written"] == 256 and s["bytes_read"] == 256
            assert s["doorbells"] == 2


class TestMemoryNode:
    def test_alloc_bump_and_exhaustion(self):
        with MemoryNode("a0", 1024) as node:
            a = node.alloc(100)
            b = node.alloc(100)
            assert b >= a + 100 and b % 64 == 0
            with pytest.raises(MemoryError):
                node.alloc(2048)

    def test_cross_device_staging_counts_ops(self):
        with MemoryNode("a1", 1 << 16) as node:
            qp = QueuePair(node)
            qp.write(MemoryRegion(np.ones(128, np.uint8)), 0,
                     node.alloc(128), 128)
            assert node.ops == 1


class TestAddressMap:
    def test_multi_node_routing_splits_ranges(self):
        n0, n1 = MemoryNode("m0", 1 << 16), MemoryNode("m1", 1 << 16)
        try:
            amap = AddressMap.striped([n0, n1], 1 << 16)   # 32 KB each
            src = np.random.default_rng(1).integers(
                0, 256, 40000, dtype=np.uint8)
            qp = QueuePair(amap)
            qp.write(MemoryRegion(src), 0, 0, 40000)       # spans both
            assert n0.bytes_in == 32768
            assert n1.bytes_in == 40000 - 32768
            back = np.zeros(40000, np.uint8)
            qp.read(MemoryRegion(back), 0, 0, 40000)
            np.testing.assert_array_equal(back, src)
        finally:
            n0.close()
            n1.close()

    def test_resolve_routes_to_correct_node(self):
        n0, n1 = MemoryNode("m2", 1 << 12), MemoryNode("m3", 1 << 12)
        try:
            amap = AddressMap()
            amap.add_range(0, 1024, n0, phys_start=0)
            amap.add_range(1024, 2048, n1, phys_start=512)
            (node, phys, nbytes, off), = amap.resolve(1500, 100)
            assert node is n1 and phys == 512 + (1500 - 1024)
            assert nbytes == 100 and off == 0
        finally:
            n0.close()
            n1.close()

    def test_unmapped_hole_rejected(self):
        with MemoryNode("m4", 1 << 12) as node:
            amap = AddressMap()
            amap.add_range(0, 512, node)
            with pytest.raises(ValueError, match="unmapped"):
                amap.resolve(256, 512)

    def test_overlapping_range_rejected(self):
        with MemoryNode("m5", 1 << 12) as node:
            amap = AddressMap()
            amap.add_range(0, 512, node)
            with pytest.raises(ValueError, match="overlap"):
                amap.add_range(256, 768, node, phys_start=512)


class TestBackends:
    def test_local_backend_roundtrip_and_accounting(self):
        be = LocalHostBackend(4, 64)
        v = np.arange(64, dtype=np.uint8)
        be.store(2, v)
        np.testing.assert_array_equal(be.load(2), v)
        s = be.stats()
        assert s["bytes_stored"] == 64 and s["bytes_loaded"] == 64

    def test_remote_backend_roundtrip_multi_node(self):
        be = RemoteBackend(n_pages=8, page_bytes=128, n_nodes=2,
                           doorbell_batch=4)
        try:
            rng = np.random.default_rng(2)
            pages = {p: rng.integers(0, 256, 128, dtype=np.uint8)
                     for p in range(8)}
            for p, v in pages.items():
                be.store(p, v)
            for p, v in pages.items():
                np.testing.assert_array_equal(be.load(p), v)
            assert all(n.bytes_in > 0 for n in be.amap.nodes)
        finally:
            be.close()

    def test_make_backend_factory(self):
        assert isinstance(make_backend("local", 2, 32), LocalHostBackend)
        be = make_backend("remote", 2, 32)
        assert isinstance(be, RemoteBackend)
        be.close()
        with pytest.raises(ValueError):
            make_backend("tape", 2, 32)

    def test_projected_seconds_uses_path_model(self):
        be = LocalHostBackend(2, 1 << 20)
        assert be.projected_seconds(1 << 20) > 0


class TestTieredStore:
    def _fill(self, store, n):
        for p in range(n):
            store.write_page(p, np.full(store.page_shape, p, np.float32))

    @pytest.mark.parametrize("kind", ["local", "remote"])
    def test_eviction_preserves_data(self, kind):
        be = make_backend(kind, 12, 4 * 8 * 4)
        with TieredStore(12, (4, 8), dtype="float32", n_hot_slots=3,
                         backend=be) as st:
            self._fill(st, 12)
            st.ensure([0, 1, 2])
            st.ensure([3, 4, 5])          # evicts 0-2
            st.ensure([6, 7])
            res = st.ensure([0])          # back intact from the cold tier
            assert float(np.asarray(res[0])[0, 0]) == 0.0
            assert st.c2h_bytes > 0 and st.h2c_bytes > 0

    def test_lru_evicts_least_recently_used(self):
        with TieredStore(6, (2, 2), dtype="float32", n_hot_slots=3) as st:
            self._fill(st, 6)
            st.ensure([0, 1, 2])
            st.ensure([0, 1])             # page 2 becomes LRU
            st.ensure([3])                # must evict page 2
            assert 2 not in st.resident_pages
            assert {0, 1, 3} == set(st.resident_pages)

    def test_byte_accounting_matches_traffic(self):
        with TieredStore(4, (8,), dtype="float32", n_hot_slots=2) as st:
            self._fill(st, 4)
            st.ensure([0, 1])
            st.ensure([2, 3])             # 2 evictions + 2 fills
            assert st.h2c_bytes == 4 * st.page_bytes
            assert st.c2h_bytes == 2 * st.page_bytes
            cold = st.stats()["cold"]
            # 4 write_page stores + 2 eviction writebacks + 4 fills loaded
            assert cold["bytes_stored"] == 6 * st.page_bytes
            assert cold["bytes_loaded"] == 4 * st.page_bytes

    def test_oversubscription_rejected(self):
        with TieredStore(8, (2, 2), n_hot_slots=2) as st:
            with pytest.raises(ValueError):
                st.ensure([0, 1, 2])

    def test_release_frees_slot(self):
        with TieredStore(4, (2,), dtype="float32", n_hot_slots=2) as st:
            self._fill(st, 4)
            st.ensure([0, 1])
            st.release(0)
            assert st.resident_pages == [1]
            st.ensure([2])                # takes the freed slot, no eviction
            assert set(st.resident_pages) == {1, 2}

    def test_remote_store_reports_remote_tier_bytes(self):
        be = RemoteBackend(n_pages=4, page_bytes=16, n_nodes=1)
        with TieredStore(4, (4,), dtype="float32", n_hot_slots=2,
                         backend=be) as st:
            self._fill(st, 4)
            st.ensure([0, 1])
            stats = st.stats()
            assert stats["cold"]["tier"] == "remote"
            assert stats["cold_bytes_moved"] > 0
            assert stats["cold_projected_seconds"] > 0


class TestAnalyticalFarPath:
    def test_doorbell_batching_amortizes_setup(self):
        m = far_memory_path()
        size = 1 << 16
        bws = [doorbell_bandwidth_gbps(m, size, b) for b in (1, 4, 16)]
        assert bws[0] < bws[1] < bws[2]
        assert bws[2] <= m.link_gbps

    def test_far_path_slower_than_local_dma_at_size(self):
        """Paper Figs 19-20: RDMA path below the raw-DMA path ceiling."""
        from repro.core.analytical import paper_pcie_ddr4
        size = 4 << 20
        far = bandwidth_gbps(far_memory_path(), size, 1, Direction.C2H)
        dma = bandwidth_gbps(paper_pcie_ddr4(), size, 1, Direction.C2H)
        assert far < dma


class TestServeIntegration:
    def _serve(self, extra):
        from repro.launch.serve import main
        return main(["--smoke", "--requests", "2", "--max-new", "4",
                     "--slots", "2"] + extra)

    def test_kv_paging_remote_smoke_and_parity(self):
        base = self._serve([])
        local = self._serve(["--kv-paging"])
        remote = self._serve(["--kv-paging", "--kv-backend", "remote"])
        # paging must not change served tokens, on either backend
        assert base["outputs"] == local["outputs"] == remote["outputs"]
        assert local["kv"]["cold"]["tier"] == "local-host"
        assert remote["kv"]["cold"]["tier"] == "remote"
        assert remote["kv"]["cold"]["bytes_stored"] > 0
        assert remote["kv"]["h2c_bytes"] > 0


class TestFarCheckpoint:
    def test_far_checkpoint_roundtrip(self, tmp_path):
        from repro.checkpoint.manager import CheckpointManager
        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                "b": jnp.ones((4,), jnp.bfloat16),
                "step": jnp.asarray(7, jnp.int32)}
        with MemoryNode("ckpt", 1 << 20) as node:
            cm = CheckpointManager(str(tmp_path))
            man = cm.save_far(7, tree, node)
            assert man["bytes"] > 0 and man["qp"]["doorbells"] >= 1
            step, back = cm.restore_far(tree, man, node)
            assert step == 7
            for k in tree:
                np.testing.assert_array_equal(np.asarray(back[k]),
                                              np.asarray(tree[k]))

    def test_periodic_far_checkpoints_reuse_addresses(self, tmp_path):
        """Passing the previous manifest as ``reuse`` must overwrite in
        place instead of bump-allocating the node to exhaustion."""
        from repro.checkpoint.manager import CheckpointManager
        tree = {"w": jnp.zeros((16, 16), jnp.float32)}
        with MemoryNode("ckpt3", 4096) as node:   # fits ~3 snapshots
            cm = CheckpointManager(str(tmp_path))
            man = cm.save_far(0, tree, node)
            brk = node._brk
            for step in range(1, 10):             # would overflow without reuse
                tree = {"w": jnp.full((16, 16), step, jnp.float32)}
                man = cm.save_far(step, tree, node, reuse=man)
            assert node._brk == brk               # no growth
            step, back = cm.restore_far(tree, man, node)
            assert step == 9
            assert float(np.asarray(back["w"])[0, 0]) == 9.0

    def test_far_checkpoint_digest_detects_corruption(self, tmp_path):
        from repro.checkpoint.manager import CheckpointManager
        tree = {"w": jnp.ones((8, 8), jnp.float32)}
        with MemoryNode("ckpt2", 1 << 20) as node:
            cm = CheckpointManager(str(tmp_path))
            man = cm.save_far(0, tree, node)
            e = man["leaves"][0]
            node.pool[e["addr"]] ^= 0xFF       # flip a byte on the node
            with pytest.raises(IOError, match="digest"):
                cm.restore_far(tree, man, node)
