"""rmem subsystem tests: verbs, memory nodes, address map, tiered store,
serve integration, far checkpoints (ISSUE 1), and the asynchronous batched
miss pipeline (ISSUE 2: doorbell-batched reads, dirty-page residency,
prefetch, overlapped two-hop fetches)."""
import jax
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.analytical import (bandwidth_gbps, doorbell_bandwidth_gbps,
                                   far_memory_path)
from repro.core.channels import CompletionMode, Direction
from repro.rmem import (AddressMap, CompletionQueue, LocalHostBackend,
                        MemoryNode, MemoryRegion, QueuePair, RemoteBackend,
                        TieredStore, WCStatus, make_backend)


class TestVerbs:
    def test_one_sided_write_read_roundtrip_bit_exact(self):
        with MemoryNode("n0", 1 << 20) as node:
            src = np.random.default_rng(0).integers(
                0, 256, 4096, dtype=np.uint8)
            addr = node.alloc(4096)
            qp = QueuePair(node)
            wc = qp.write(MemoryRegion(src), 0, addr, 4096)
            assert wc.status == WCStatus.SUCCESS
            back = np.zeros(4096, np.uint8)
            qp.read(MemoryRegion(back), 0, addr, 4096)
            np.testing.assert_array_equal(back, src)
            assert node.bytes_in == 4096 and node.bytes_out == 4096

    def test_doorbell_batching_fewer_completions(self):
        n = 8
        with MemoryNode("n1", 1 << 20) as node:
            mr = MemoryRegion(np.ones(n * 512, np.uint8))
            qp = QueuePair(node, doorbell_batch=n)
            base = node.alloc(n * 512)
            for i in range(n):
                qp.post_write(mr, i * 512, base + i * 512, 512)
            qp.flush()
            assert qp.wrs_posted == n
            assert qp.cq.n_completions < n
            assert qp.cq.n_completions == 1
            np.testing.assert_array_equal(
                node.pool[base:base + n * 512], np.ones(n * 512, np.uint8))

    def test_batched_completion_carries_batch_totals(self):
        with MemoryNode("n2", 1 << 20) as node:
            mr = MemoryRegion(np.ones(4 * 256, np.uint8))
            qp = QueuePair(node, doorbell_batch=4)
            base = node.alloc(4 * 256)
            for i in range(4):
                qp.post_write(mr, i * 256, base + i * 256, 256)
            wc = qp.cq.wait(1)[0]
            assert wc.batch_wrs == 4
            assert wc.batch_bytes == 4 * 256

    def test_interrupt_mode_fires_callback(self):
        import threading
        fired = threading.Event()
        cq = CompletionQueue(CompletionMode.INTERRUPT,
                             on_completion=lambda wc: fired.set())
        with MemoryNode("n3", 1 << 16) as node:
            qp = QueuePair(node, cq=cq)
            qp.write(MemoryRegion(np.ones(64, np.uint8)), 0,
                     node.alloc(64), 64)
            assert fired.wait(10)

    def test_mr_bounds_checked_at_post(self):
        with MemoryNode("n4", 1 << 16) as node:
            qp = QueuePair(node)
            mr = MemoryRegion(np.ones(64, np.uint8))
            with pytest.raises(ValueError, match="out of bounds"):
                qp.post_write(mr, 32, 0, 64)

    def test_out_of_pool_write_surfaces_error(self):
        with MemoryNode("n5", 1024) as node:
            qp = QueuePair(node, doorbell_batch=4)
            qp.post_write(MemoryRegion(np.ones(512, np.uint8)), 0, 900, 512)
            with pytest.raises(IndexError, match="out of pool"):
                qp.flush()

    def test_qp_stats_account_traffic(self):
        with MemoryNode("n6", 1 << 16) as node:
            qp = QueuePair(node)
            addr = node.alloc(256)
            qp.write(MemoryRegion(np.ones(256, np.uint8)), 0, addr, 256)
            buf = np.zeros(256, np.uint8)
            qp.read(MemoryRegion(buf), 0, addr, 256)
            s = qp.stats()
            assert s["bytes_written"] == 256 and s["bytes_read"] == 256
            assert s["doorbells"] == 2


class TestMemoryNode:
    def test_alloc_bump_and_exhaustion(self):
        with MemoryNode("a0", 1024) as node:
            a = node.alloc(100)
            b = node.alloc(100)
            assert b >= a + 100 and b % 64 == 0
            with pytest.raises(MemoryError):
                node.alloc(2048)

    def test_cross_device_staging_counts_ops(self):
        with MemoryNode("a1", 1 << 16) as node:
            qp = QueuePair(node)
            qp.write(MemoryRegion(np.ones(128, np.uint8)), 0,
                     node.alloc(128), 128)
            assert node.ops == 1


class TestAddressMap:
    def test_multi_node_routing_splits_ranges(self):
        n0, n1 = MemoryNode("m0", 1 << 16), MemoryNode("m1", 1 << 16)
        try:
            amap = AddressMap.striped([n0, n1], 1 << 16)   # 32 KB each
            src = np.random.default_rng(1).integers(
                0, 256, 40000, dtype=np.uint8)
            qp = QueuePair(amap)
            qp.write(MemoryRegion(src), 0, 0, 40000)       # spans both
            assert n0.bytes_in == 32768
            assert n1.bytes_in == 40000 - 32768
            back = np.zeros(40000, np.uint8)
            qp.read(MemoryRegion(back), 0, 0, 40000)
            np.testing.assert_array_equal(back, src)
        finally:
            n0.close()
            n1.close()

    def test_resolve_routes_to_correct_node(self):
        n0, n1 = MemoryNode("m2", 1 << 12), MemoryNode("m3", 1 << 12)
        try:
            amap = AddressMap()
            amap.add_range(0, 1024, n0, phys_start=0)
            amap.add_range(1024, 2048, n1, phys_start=512)
            (node, phys, nbytes, off), = amap.resolve(1500, 100)
            assert node is n1 and phys == 512 + (1500 - 1024)
            assert nbytes == 100 and off == 0
        finally:
            n0.close()
            n1.close()

    def test_unmapped_hole_rejected(self):
        with MemoryNode("m4", 1 << 12) as node:
            amap = AddressMap()
            amap.add_range(0, 512, node)
            with pytest.raises(ValueError, match="unmapped"):
                amap.resolve(256, 512)

    def test_overlapping_range_rejected(self):
        with MemoryNode("m5", 1 << 12) as node:
            amap = AddressMap()
            amap.add_range(0, 512, node)
            with pytest.raises(ValueError, match="overlap"):
                amap.add_range(256, 768, node, phys_start=512)

    def test_resolve_span_crossing_node_boundaries(self):
        """A span covering parts of three ranges must split exactly at
        every boundary, with per-piece phys offsets and local offsets
        that tile the request (ISSUE 5 satellite)."""
        n0, n1, n2 = (MemoryNode(f"mx{i}", 1 << 12) for i in range(3))
        try:
            amap = AddressMap()
            amap.add_range(0, 100, n0, phys_start=0)
            amap.add_range(100, 250, n1, phys_start=40)
            amap.add_range(250, 300, n2, phys_start=7)
            pieces = amap.resolve(60, 220)      # [60, 280)
            assert [(p[0].name, p[1], p[2], p[3]) for p in pieces] == [
                ("mx0", 60, 40, 0),             # [60, 100): tail of n0
                ("mx1", 40, 150, 40),           # [100, 250): all of n1
                ("mx2", 7, 30, 190),            # [250, 280): head of n2
            ]
            assert sum(p[2] for p in pieces) == 220
            # local offsets tile the request contiguously
            off = 0
            for _, _, nbytes, local in pieces:
                assert local == off
                off += nbytes
            # exact-boundary start lands on the second range, not a hole
            (node, phys, nbytes, local), = amap.resolve(100, 10)
            assert node is n1 and phys == 40 and local == 0
            # last byte of the map resolves; one past raises
            (node, phys, nbytes, _), = amap.resolve(299, 1)
            assert node is n2 and phys == 7 + 49 and nbytes == 1
            with pytest.raises(ValueError, match="unmapped"):
                amap.resolve(299, 2)
        finally:
            for n in (n0, n1, n2):
                n.close()

    def test_striped_non_divisible_remainder_stripe(self):
        """Striping a total that doesn't divide by the node count must
        give the last node exactly the remainder — full coverage, no
        overlap, no byte past the total (ISSUE 5 satellite)."""
        nodes = [MemoryNode(f"ms{i}", 1 << 12) for i in range(3)]
        try:
            total = 1000                        # ceil(1000/3) = 334
            amap = AddressMap.striped(nodes, total, align=1)
            spans = [(e.vaddr_start, e.vaddr_end) for e in amap.entries]
            assert spans == [(0, 334), (334, 668), (668, 1000)]
            assert spans[-1][1] - spans[-1][0] == 1000 - 2 * 334  # 332
            # the whole space resolves with pieces summing to total
            pieces = amap.resolve(0, total)
            assert sum(p[2] for p in pieces) == total
            assert [p[0].name for p in pieces] == ["ms0", "ms1", "ms2"]
            with pytest.raises(ValueError, match="unmapped"):
                amap.resolve(total - 1, 2)
            # a stripe-boundary-straddling write/read roundtrips bit-exact
            src = np.random.default_rng(9).integers(
                0, 256, 200, dtype=np.uint8)
            qp = QueuePair(amap)
            qp.write(MemoryRegion(src), 0, 300, 200)   # spans 334
            back = np.zeros(200, np.uint8)
            qp.read(MemoryRegion(back), 0, 300, 200)
            np.testing.assert_array_equal(back, src)
        finally:
            for n in nodes:
                n.close()

    def test_membership_epoch_monotonic_and_propagated(self):
        """ISSUE 5: the fabric stamps membership epochs down through the
        map into every node; rollback attempts raise."""
        nodes = [MemoryNode(f"me{i}", 1 << 10) for i in range(2)]
        try:
            amap = AddressMap.striped(nodes, 1024)
            assert amap.epoch == 0 and all(n.epoch == 0 for n in nodes)
            amap.set_epoch(3)
            assert all(n.epoch == 3 for n in nodes)
            with pytest.raises(ValueError, match="monotonic"):
                amap.set_epoch(2)
            with pytest.raises(ValueError, match="monotonic"):
                nodes[0].set_epoch(1)
        finally:
            for n in nodes:
                n.close()


class TestBackends:
    def test_local_backend_roundtrip_and_accounting(self):
        be = LocalHostBackend(4, 64)
        v = np.arange(64, dtype=np.uint8)
        be.store(2, v)
        np.testing.assert_array_equal(be.load(2), v)
        s = be.stats()
        assert s["bytes_stored"] == 64 and s["bytes_loaded"] == 64

    def test_remote_backend_roundtrip_multi_node(self):
        be = RemoteBackend(n_pages=8, page_bytes=128, n_nodes=2,
                           doorbell_batch=4)
        try:
            rng = np.random.default_rng(2)
            pages = {p: rng.integers(0, 256, 128, dtype=np.uint8)
                     for p in range(8)}
            for p, v in pages.items():
                be.store(p, v)
            for p, v in pages.items():
                np.testing.assert_array_equal(be.load(p), v)
            assert all(n.bytes_in > 0 for n in be.amap.nodes)
        finally:
            be.close()

    def test_make_backend_factory(self):
        assert isinstance(make_backend("local", 2, 32), LocalHostBackend)
        be = make_backend("remote", 2, 32)
        assert isinstance(be, RemoteBackend)
        be.close()
        with pytest.raises(ValueError):
            make_backend("tape", 2, 32)

    def test_projected_seconds_uses_path_model(self):
        be = LocalHostBackend(2, 1 << 20)
        assert be.projected_seconds(1 << 20) > 0


class TestTieredStore:
    def _fill(self, store, n):
        for p in range(n):
            store.write_page(p, np.full(store.page_shape, p, np.float32))

    @pytest.mark.parametrize("kind", ["local", "remote"])
    def test_eviction_preserves_data(self, kind):
        be = make_backend(kind, 12, 4 * 8 * 4)
        with TieredStore(12, (4, 8), dtype="float32", n_hot_slots=3,
                         backend=be) as st:
            self._fill(st, 12)
            st.ensure([0, 1, 2])
            st.update_page(1, np.full((4, 8), 41.0, np.float32))  # dirty
            st.ensure([3, 4, 5])          # evicts 0-2 (1 needs writeback)
            st.ensure([6, 7])
            res = st.ensure([0, 1])       # back intact from the cold tier
            assert float(np.asarray(res[0])[0, 0]) == 0.0
            assert float(np.asarray(res[1])[0, 0]) == 41.0  # dirty persisted
            # only the dirty page paid the C2H drain on eviction
            assert st.c2h_bytes == st.page_bytes
            assert st.h2c_bytes > 0

    def test_lru_evicts_least_recently_used(self):
        with TieredStore(6, (2, 2), dtype="float32", n_hot_slots=3) as st:
            self._fill(st, 6)
            st.ensure([0, 1, 2])
            st.ensure([0, 1])             # page 2 becomes LRU
            st.ensure([3])                # must evict page 2
            assert 2 not in st.resident_pages
            assert {0, 1, 3} == set(st.resident_pages)

    def test_byte_accounting_matches_traffic(self):
        with TieredStore(4, (8,), dtype="float32", n_hot_slots=2) as st:
            self._fill(st, 4)
            st.ensure([0, 1])
            st.ensure([2, 3])             # 2 clean evictions + 2 fills
            assert st.h2c_bytes == 4 * st.page_bytes
            # evicted pages were loaded straight from cold (never dirtied),
            # so eviction skips both the C2H drain and the cold writeback
            assert st.c2h_bytes == 0
            cold = st.stats()["cold"]
            # 4 write_page stores only; 4 fills loaded
            assert cold["bytes_stored"] == 4 * st.page_bytes
            assert cold["bytes_loaded"] == 4 * st.page_bytes
            assert st.stats()["clean_evictions"] == 2
            assert st.stats()["writeback_bytes_skipped"] == \
                2 * st.page_bytes

    def test_oversubscription_rejected(self):
        with TieredStore(8, (2, 2), n_hot_slots=2) as st:
            with pytest.raises(ValueError):
                st.ensure([0, 1, 2])

    def test_release_frees_slot(self):
        with TieredStore(4, (2,), dtype="float32", n_hot_slots=2) as st:
            self._fill(st, 4)
            st.ensure([0, 1])
            st.release(0)
            assert st.resident_pages == [1]
            st.ensure([2])                # takes the freed slot, no eviction
            assert set(st.resident_pages) == {1, 2}

    def test_remote_store_reports_remote_tier_bytes(self):
        be = RemoteBackend(n_pages=4, page_bytes=16, n_nodes=1)
        with TieredStore(4, (4,), dtype="float32", n_hot_slots=2,
                         backend=be) as st:
            self._fill(st, 4)
            st.ensure([0, 1])
            stats = st.stats()
            assert stats["cold"]["tier"] == "remote"
            assert stats["cold_bytes_moved"] > 0
            assert stats["cold_projected_seconds"] > 0


class TestAnalyticalFarPath:
    def test_doorbell_batching_amortizes_setup(self):
        m = far_memory_path()
        size = 1 << 16
        bws = [doorbell_bandwidth_gbps(m, size, b) for b in (1, 4, 16)]
        assert bws[0] < bws[1] < bws[2]
        assert bws[2] <= m.link_gbps

    def test_far_path_slower_than_local_dma_at_size(self):
        """Paper Figs 19-20: RDMA path below the raw-DMA path ceiling."""
        from repro.core.analytical import paper_pcie_ddr4
        size = 4 << 20
        far = bandwidth_gbps(far_memory_path(), size, 1, Direction.C2H)
        dma = bandwidth_gbps(paper_pcie_ddr4(), size, 1, Direction.C2H)
        assert far < dma


class TestServeIntegration:
    def _serve(self, extra):
        from repro.launch.serve import main
        return main(["--smoke", "--requests", "2", "--max-new", "4",
                     "--slots", "2"] + extra)

    def test_kv_paging_remote_smoke_and_parity(self):
        base = self._serve([])
        local = self._serve(["--kv-paging"])
        remote = self._serve(["--access-path", "verbs"])
        # paging must not change served tokens, on either backend
        assert base["outputs"] == local["outputs"] == remote["outputs"]
        assert local["kv"]["cold"]["tier"] == "local-host"
        assert remote["kv"]["cold"]["tier"] == "remote"
        assert remote["kv"]["cold"]["bytes_stored"] > 0
        assert remote["kv"]["h2c_bytes"] > 0

    def test_kv_backend_flag_deprecated_alias(self):
        with pytest.warns(DeprecationWarning, match="--kv-backend"):
            remote = self._serve(["--kv-paging", "--kv-backend", "remote"])
        assert remote["access_path"] == "verbs"
        assert remote["kv"]["cold"]["tier"] == "remote"


class TestFarCheckpoint:
    def test_far_checkpoint_roundtrip(self, tmp_path):
        from repro.checkpoint.manager import CheckpointManager
        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                "b": jnp.ones((4,), jnp.bfloat16),
                "step": jnp.asarray(7, jnp.int32)}
        with MemoryNode("ckpt", 1 << 20) as node:
            cm = CheckpointManager(str(tmp_path))
            man = cm.save_far(7, tree, node)
            assert man["bytes"] > 0 and man["qp"]["doorbells"] >= 1
            step, back = cm.restore_far(tree, man, node)
            assert step == 7
            for k in tree:
                np.testing.assert_array_equal(np.asarray(back[k]),
                                              np.asarray(tree[k]))

    def test_periodic_far_checkpoints_reuse_addresses(self, tmp_path):
        """Passing the previous manifest as ``reuse`` must overwrite in
        place instead of bump-allocating the node to exhaustion."""
        from repro.checkpoint.manager import CheckpointManager
        tree = {"w": jnp.zeros((16, 16), jnp.float32)}
        with MemoryNode("ckpt3", 4096) as node:   # fits ~3 snapshots
            cm = CheckpointManager(str(tmp_path))
            man = cm.save_far(0, tree, node)
            brk = node._brk
            for step in range(1, 10):             # would overflow without reuse
                tree = {"w": jnp.full((16, 16), step, jnp.float32)}
                man = cm.save_far(step, tree, node, reuse=man)
            assert node._brk == brk               # no growth
            step, back = cm.restore_far(tree, man, node)
            assert step == 9
            assert float(np.asarray(back["w"])[0, 0]) == 9.0

    def test_far_checkpoint_digest_detects_corruption(self, tmp_path):
        from repro.checkpoint.manager import CheckpointManager
        tree = {"w": jnp.ones((8, 8), jnp.float32)}
        with MemoryNode("ckpt2", 1 << 20) as node:
            cm = CheckpointManager(str(tmp_path))
            man = cm.save_far(0, tree, node)
            e = man["leaves"][0]
            node.pool[e["addr"]] ^= 0xFF       # flip a byte on the node
            with pytest.raises(IOError, match="digest"):
                cm.restore_far(tree, man, node)


class TestMissPipeline:
    """ISSUE 2: doorbell-batched reads, dirty residency, prefetch overlap."""

    def test_flush_is_conditional_on_outstanding_wrs(self):
        with MemoryNode("mp0", 1 << 16) as node:
            qp = QueuePair(node, doorbell_batch=4)
            assert qp.outstanding_wrs == 0
            qp.flush()                      # no-op: nothing rung, no wait
            assert qp.doorbells == 0
            qp.post_write(MemoryRegion(np.ones(64, np.uint8)), 0,
                          node.alloc(64), 64)
            assert qp.outstanding_wrs == 1
            qp.flush()
            assert qp.doorbells == 1 and qp.outstanding_wrs == 0

    def test_remote_load_fences_only_when_writes_pending(self):
        be = RemoteBackend(n_pages=4, page_bytes=64, n_nodes=1,
                           doorbell_batch=4)
        try:
            be.store(0, np.full(64, 7, np.uint8))
            be.flush()
            # idle QP: the load's fence is a fast-path no-op — the only
            # doorbell rung is the read's own
            d0 = be.qp.doorbells
            assert be.load(0)[0] == 7
            assert be.qp.doorbells == d0 + 1
            be.store(1, np.full(64, 9, np.uint8))   # pending unsignaled WR
            assert be.qp.outstanding_wrs == 1
            d1 = be.qp.doorbells
            assert be.load(1)[0] == 9               # fenced: write rung too
            assert be.qp.doorbells == d1 + 2
        finally:
            be.close()

    def test_conditional_fence_still_surfaces_deferred_errors(self):
        """A failed unsignaled doorbell that drained while nothing was
        outstanding must still raise on the next fence (flush fast path)
        and on batched-load joins — not silently return stale bytes."""
        be = RemoteBackend(n_pages=4, page_bytes=64, n_nodes=1,
                           doorbell_batch=4)
        try:
            be.store(0, np.full(64, 7, np.uint8))
            be.flush()
            boom = IOError("node-side write failure")
            be.qp._async_errors[1] = boom   # a drained doorbell's error
            with pytest.raises(IOError, match="node-side"):
                be.load(0)
            assert be.load(0)[0] == 7       # raised once, then recovered
            be.qp._async_errors[2] = boom
            with pytest.raises(IOError, match="node-side"):
                be.load_many_async([0]).wait()
        finally:
            be.close()

    def test_batched_reads_ordered_after_interleaved_writes(self):
        """Doorbell-batched reads posted on the same QP observe writes
        posted earlier — including unsignaled writes still pending in the
        send queue — without an explicit flush."""
        be = RemoteBackend(n_pages=8, page_bytes=64, n_nodes=1,
                           doorbell_batch=4)
        try:
            for p in range(8):
                be.store(p, np.full(64, p, np.uint8))
            be.flush()
            # re-store two pages; the writes stay pending (unsignaled, the
            # doorbell has not been rung), then batch-read them back
            be.store(2, np.full(64, 200, np.uint8))
            be.store(5, np.full(64, 205, np.uint8))
            out = be.load_many([2, 5, 7])
            assert out[0][0] == 200 and out[1][0] == 205 and out[2][0] == 7
        finally:
            be.close()

    def test_load_many_spans_address_map_node_boundary(self):
        # 5 pages x 768 B striped over 2 nodes (1920 B each): page 2
        # occupies [1536, 2304) and straddles the boundary at 1920
        be = RemoteBackend(n_pages=5, page_bytes=768, n_nodes=2,
                           doorbell_batch=4)
        try:
            vals = {p: np.random.default_rng(p).integers(
                0, 256, 768, dtype=np.uint8) for p in range(5)}
            be.store_many(list(vals), list(vals.values()))
            out = be.load_many(list(vals))
            for i, p in enumerate(vals):
                np.testing.assert_array_equal(out[i], vals[p])
            assert all(n.bytes_out > 0 for n in be.amap.nodes)
        finally:
            be.close()

    def test_node_coalesces_batched_reads_into_one_hop(self):
        be = RemoteBackend(n_pages=8, page_bytes=256, n_nodes=1,
                           doorbell_batch=8)
        try:
            be.store_many(range(8), [np.full(256, p, np.uint8)
                                     for p in range(8)])
            be.flush()                      # drain the write doorbell first
            hops0 = be.amap.nodes[0].staged_hops
            be.load_many(list(range(8)))
            node = be.amap.nodes[0]
            assert node.staged_hops == hops0 + 1    # 8 reads, one hop
            assert node.coalesced_runs >= 1
        finally:
            be.close()

    def test_store_many_async_wait_fences_writes(self):
        be = RemoteBackend(n_pages=6, page_bytes=128, n_nodes=1,
                           doorbell_batch=4)
        try:
            vals = [np.full(128, 10 + p, np.uint8) for p in range(6)]
            io = be.store_many_async(range(6), vals)
            io.wait()
            assert be.qp.outstanding_wrs == 0
            assert be.amap.nodes[0].bytes_in >= 6 * 128
        finally:
            be.close()

    @pytest.mark.parametrize("kind", ["local", "remote"])
    def test_prefetch_then_ensure_bit_identical(self, kind):
        rng = np.random.default_rng(4)
        data = [rng.standard_normal((4, 8)).astype(np.float32)
                for _ in range(10)]
        page_bytes = 4 * 8 * 4

        def build():
            st = TieredStore(10, (4, 8), dtype="float32", n_hot_slots=4,
                             backend=make_backend(kind, 10, page_bytes))
            for p, v in enumerate(data):
                st.write_page(p, v)
            return st
        with build() as sync, build() as pre:
            want = sync.ensure([0, 1, 2, 3])
            pre.prefetch([0, 1, 2, 3])      # fetch starts in the background
            got = pre.ensure([0, 1, 2, 3])
            for p in range(4):
                np.testing.assert_array_equal(np.asarray(got[p]),
                                              np.asarray(want[p]))
                np.testing.assert_array_equal(np.asarray(got[p]), data[p])
            assert pre.stats()["prefetch_hits"] == 4
            assert sync.stats()["prefetch_hits"] == 0

    def test_ensure_never_evicts_page_requested_in_same_call(self):
        with TieredStore(4, (8,), dtype="float32", n_hot_slots=2) as st:
            for p in range(4):
                st.write_page(p, np.full(8, p, np.float32))
            st.ensure([0])
            st.ensure([1])                  # page 0 is now the LRU slot
            res = st.ensure([0, 2])         # must evict 1, never 0
            assert set(st.resident_pages) == {0, 2}
            assert float(np.asarray(res[0])[0]) == 0.0

    def test_ensure_failure_rolls_back_unmapped_residency(self):
        """If a group's fetch fails mid-pipeline, no page of that ensure
        may be left 'resident' pointing at a slot whose device array never
        landed — and the store must keep working afterwards."""
        from repro.rmem.backend import PendingIO

        class FlakyBackend(LocalHostBackend):
            doorbell_batch = 2              # forces two-page miss groups

            def load_many_async(self, pages):
                pages = list(pages)
                if 2 in pages:
                    def boom(_t):
                        raise IOError("fetch failed")
                    return PendingIO(boom)
                return super().load_many_async(pages)

        be = FlakyBackend(6, 32)
        with TieredStore(6, (8,), dtype="float32", n_hot_slots=4,
                         backend=be) as st:
            for p in range(6):
                st.write_page(p, np.full(8, p, np.float32))
            with pytest.raises(IOError, match="fetch failed"):
                st.ensure([0, 1, 2, 3])     # group [2, 3] fails
            assert st.resident_pages == []  # nothing half-mapped
            res = st.ensure([0, 1])         # clean recovery
            assert float(np.asarray(res[0])[0]) == 0.0
            assert float(np.asarray(res[1])[0]) == 1.0

    def test_write_page_invalidates_stale_prefetch(self):
        with TieredStore(6, (8,), dtype="float32", n_hot_slots=2) as st:
            st.write_page(0, np.zeros(8, np.float32))
            st.prefetch([0])
            st.write_page(0, np.full(8, 3.0, np.float32))  # newer bytes
            res = st.ensure([0])
            assert float(np.asarray(res[0])[0]) == 3.0

    def test_write_page_fences_inflight_remote_prefetch(self):
        """write_page on a page whose prefetch READ is still executing
        must fence that read first — otherwise the read scatters stale
        bytes over the new value in the shared staging row and the store
        pushes them cold."""
        node = MemoryNode("racer", (1 << 21) + (1 << 14))
        be = RemoteBackend(n_pages=2, page_bytes=4096, nodes=[node],
                           doorbell_batch=1)
        with TieredStore(2, (4096,), dtype="uint8", n_hot_slots=1,
                         backend=be) as st:
            st.write_page(0, np.full(4096, 1, np.uint8))
            be.flush()
            # clog the node's FIFO with busy-work so the prefetch read
            # stays in flight across the write_page call
            side = QueuePair(node)
            buf = MemoryRegion(np.zeros(1 << 20, np.uint8))
            addr = node.alloc(1 << 20)
            for _ in range(4):
                side.post_write(buf, 0, addr, 1 << 20)
                side.ring_doorbell()
            st.prefetch([0])                # read queued behind the clog
            st.write_page(0, np.full(4096, 9, np.uint8))
            res = st.ensure([0])
            assert int(np.asarray(res[0])[0]) == 9
            assert int(be.load(0)[0]) == 9  # cold copy holds the new bytes
            side.flush()
        node.close()

    def test_batched_paths_drain_completion_queue(self):
        be = RemoteBackend(n_pages=8, page_bytes=64, n_nodes=1,
                           doorbell_batch=4)
        try:
            vals = [np.full(64, p, np.uint8) for p in range(8)]
            for _ in range(3):
                be.store_many_async(range(8), vals).wait()
                be.load_many(list(range(8)))
            assert len(be.cq._ring) == 0    # no unbounded completion pile
        finally:
            be.close()

    def test_collector_error_not_redelivered_to_next_fence(self):
        with MemoryNode("mp9", 1024) as node:
            qp = QueuePair(node, doorbell_batch=2)
            mr = MemoryRegion(np.zeros(512, np.uint8))
            with qp.collect_doorbells() as coll:
                qp.post_read(mr, 0, 900, 512)   # past the pool end
                qp.ring_doorbell()
            with pytest.raises(IndexError, match="out of pool"):
                coll.wait()
            qp.flush()      # already reported: must not re-raise

    def test_dirty_eviction_writes_back_clean_skips(self):
        with TieredStore(6, (8,), dtype="float32", n_hot_slots=2) as st:
            for p in range(6):
                st.write_page(p, np.full(8, p, np.float32))
            st.ensure([0, 1])
            st.update_page(0, np.full(8, 50.0, np.float32))
            assert st.is_dirty(0) and not st.is_dirty(1)
            stored0 = st.backend.stats()["bytes_stored"]
            st.ensure([2, 3])               # evicts 0 (dirty) and 1 (clean)
            s = st.stats()
            assert s["evictions"] == 2
            assert s["clean_evictions"] == 1 and s["dirty_evictions"] == 1
            assert s["writeback_bytes_skipped"] == st.page_bytes
            # only the dirty page moved cold bytes
            assert st.backend.stats()["bytes_stored"] - stored0 == \
                st.page_bytes
            res = st.ensure([0])            # dirty data persisted
            assert float(np.asarray(res[0])[0]) == 50.0

    def test_release_writes_back_only_dirty_pages(self):
        with TieredStore(4, (8,), dtype="float32", n_hot_slots=2) as st:
            for p in range(4):
                st.write_page(p, np.full(8, p, np.float32))
            st.ensure([0, 1])
            st.update_page(0, np.full(8, 9.0, np.float32))
            stored0 = st.backend.stats()["bytes_stored"]
            st.release(0)                   # dirty: drained cold
            st.release(1)                   # clean: moves zero bytes
            assert st.backend.stats()["bytes_stored"] - stored0 == \
                st.page_bytes
            res = st.ensure([0, 1])
            assert float(np.asarray(res[0])[0]) == 9.0
            assert float(np.asarray(res[1])[0]) == 1.0

    def test_release_discard_drops_dirty_data(self):
        with TieredStore(4, (8,), dtype="float32", n_hot_slots=2) as st:
            for p in range(4):
                st.write_page(p, np.full(8, p, np.float32))
            st.ensure([0])
            st.update_page(0, np.full(8, 9.0, np.float32))
            st.release(0, writeback=False)  # explicit discard
            res = st.ensure([0])
            assert float(np.asarray(res[0])[0]) == 0.0

    @pytest.mark.parametrize("kind", ["local", "remote"])
    def test_batched_ensure_matches_serial_ensure(self, kind):
        rng = np.random.default_rng(5)
        data = [rng.standard_normal((2, 4)).astype(np.float32)
                for _ in range(8)]
        page_bytes = 2 * 4 * 4

        def build():
            kw = dict(n_nodes=2, doorbell_batch=4) if kind == "remote" \
                else {}
            return TieredStore(8, (2, 4), dtype="float32", n_hot_slots=6,
                               backend=make_backend(kind, 8, page_bytes,
                                                    **kw))
        with build() as a, build() as b:
            for p, v in enumerate(data):
                a.write_page(p, v)
                b.write_page(p, v)
            got = a.ensure([0, 1, 2, 3, 4, 5])      # one batched pipeline
            for p in range(6):
                want = b.ensure([p])[p]             # serial per-page
                np.testing.assert_array_equal(np.asarray(got[p]),
                                              np.asarray(want))


class TestServeRejection:
    def test_overlong_prompt_rejected_engine_keeps_serving(self):
        from repro.configs import get_config, reduce_for_smoke
        from repro.launch.serve import Request, ServeEngine
        from repro.models import transformer as T
        cfg = reduce_for_smoke(get_config("qwen2-0.5b"))
        params = T.tree_init(T.param_defs(cfg), cfg,
                             jax.random.PRNGKey(0))
        eng = ServeEngine(cfg, params, batch_slots=2, max_len=32)
        rng = np.random.default_rng(0)
        eng.submit(Request(rid=0, prompt=rng.integers(
            0, cfg.vocab, 40).astype(np.int32), max_new=4))   # too long
        eng.submit(Request(rid=1, prompt=rng.integers(
            0, cfg.vocab, 8).astype(np.int32), max_new=4))
        eng.run_until_drained()
        failed = [r for r in eng.done if r.failed is not None]
        served = [r for r in eng.done if r.failed is None]
        assert len(failed) == 1 and failed[0].rid == 0
        assert "max_len" in failed[0].failed
        assert len(served) == 1 and len(served[0].out_tokens) == 4


class TestStagedWritebacks:
    """ISSUE 9 satellite: resident-page updates batch into ONE staged
    H2C per call group, and ``ensure_packed`` hands fetch groups to the
    fused installer unsplit."""

    def _fill(self, store, n):
        for p in range(n):
            store.write_page(p, np.full(store.page_shape, p, np.float32))

    def test_update_pages_one_staged_transfer(self):
        with TieredStore(6, (4,), dtype="float32", n_hot_slots=4) as st:
            self._fill(st, 6)
            st.ensure([0, 1, 2, 3])
            st.update_pages({p: np.full((4,), 50.0 + p, np.float32)
                             for p in range(4)})
            stats = st.stats()
            assert stats["staged_hops"] == 1
            assert stats["staged_hops_saved"] == 3
            res = st.ensure([0, 1, 2, 3])
            for p in range(4):
                np.testing.assert_array_equal(
                    np.asarray(res[p]), np.full((4,), 50.0 + p, np.float32))
            # dirty: evict and reload round-trips the staged values
            st.ensure([4, 5])
            res = st.ensure([0, 1])
            assert float(np.asarray(res[0])[0]) == 50.0

    def test_update_page_still_one_hop_each(self):
        with TieredStore(4, (4,), dtype="float32", n_hot_slots=2) as st:
            self._fill(st, 4)
            st.ensure([0, 1])
            st.update_page(0, np.full((4,), 9.0, np.float32))
            st.update_page(1, np.full((4,), 8.0, np.float32))
            stats = st.stats()
            assert stats["staged_hops"] == 2
            assert stats["staged_hops_saved"] == 0

    def test_write_pages_updates_resident_and_cold(self):
        with TieredStore(4, (4,), dtype="float32", n_hot_slots=2) as st:
            self._fill(st, 4)
            st.ensure([0, 1])
            st.write_pages({0: np.full((4,), 7.0, np.float32),
                            3: np.full((4,), 6.0, np.float32)})
            assert float(np.asarray(st.read_page(0))[0]) == 7.0
            res = st.ensure([3])        # cold page took the new bytes
            assert float(np.asarray(res[3])[0]) == 6.0
            # write_page makes the page clean (cold copy authoritative)
            assert 0 not in st.dirty_pages

    def test_ensure_packed_groups_stay_whole(self):
        be = make_backend("remote", 8, 16, doorbell_batch=4)
        with TieredStore(8, (4,), dtype="float32", n_hot_slots=4,
                         backend=be) as st:
            self._fill(st, 8)
            packed = st.ensure_packed([0, 1, 2, 3])
            rows = {p: r for p, (_, r) in packed.items()}
            bufs = {id(b) for b, _ in packed.values()}
            # one doorbell group of 4: one staged buffer, distinct rows
            assert len(bufs) == 1
            assert sorted(rows.values()) == [0, 1, 2, 3]
            for p, (buf, row) in packed.items():
                np.testing.assert_array_equal(
                    np.asarray(buf[row]).view(np.float32),
                    np.full((4,), p, np.float32))
            # a later per-page read materializes the same bytes
            np.testing.assert_array_equal(
                np.asarray(st.read_page(2)), np.full((4,), 2.0, np.float32))

    def test_ensure_packed_resident_page_row_none(self):
        with TieredStore(4, (4,), dtype="float32", n_hot_slots=2) as st:
            self._fill(st, 4)
            st.ensure([1])              # materialized single-page fetch
            packed = st.ensure_packed([1])
            buf, row = packed[1]
            assert row is None
            np.testing.assert_array_equal(
                np.asarray(buf), np.full((4,), 1.0, np.float32))
